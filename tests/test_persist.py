"""Persistence: save/load roundtrip, the disk tier, and measured I/O.

The contract under test:

  * ``save`` -> ``load`` returns an engine whose search output (ids,
    dists, every stats counter) is bit-identical to the freshly built
    in-memory engine, in all five modes, for both the memory and the
    disk record tier — load never rebuilds the graph or retrains PQ.
    ``save(shards=k)`` (per-shard record segments + manifest) preserves
    the same contract, and v1 files (monolithic records, no manifest)
    still read.
  * The disk tier *measures* its reads: ``DiskRecordStore.pages_read``
    deltas reconcile exactly with summed ``SearchStats.n_ios`` (x pages
    per record), gate reads strictly fewer pages than post on a
    selective filter, the coalesced reader never reads more unique
    sectors than requested, and the cache tier composes on top
    unchanged.  A disk-tier load keeps ``engine.vectors`` a lazy host
    view — no device materialization of the corpus.
  * The format rejects bad magic, newer versions, truncated files, and
    lying/stale shard manifests or segments.
"""
import os
import shutil

import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.store import (
    FORMAT_VERSION,
    PAGE_BYTES,
    DiskRecordStore,
    IndexFormatError,
    is_lazy_host,
    read_header,
    read_index,
)
from repro.store.format import pack_records, record_sector_bytes

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")
RECORD = 4096  # tiny-corpus records round up to one 4 KB sector


def _search(engine, queries, mode, L=64, W=4):
    kind = None if mode == "unfiltered" else "label"
    params = None if mode == "unfiltered" else np.zeros(queries.shape[0], np.int32)
    return engine.search(
        queries, filter_kind=kind, filter_params=params,
        search_config=SearchConfig(mode=mode, search_l=L, beam_width=W),
    )


@pytest.fixture(scope="module")
def index_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("index") / "tiny.gann")
    tiny_engine.save(path)
    return path


@pytest.fixture(scope="module")
def mem_engine(index_path):
    return GateANNEngine.load(index_path)


@pytest.fixture(scope="module")
def disk_engine(index_path):
    return GateANNEngine.load(index_path, store_tier="disk")


# -- roundtrip --------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_roundtrip_bit_identical(tiny_engine, tiny_corpus, mem_engine,
                                 disk_engine, mode):
    """Loaded engines (both tiers) match the freshly built one exactly."""
    _, _, queries = tiny_corpus
    base = _search(tiny_engine, queries, mode)
    for name, eng in (("memory", mem_engine), ("disk", disk_engine)):
        out = _search(eng, queries, mode)
        msg = f"tier={name} mode={mode}"
        np.testing.assert_array_equal(np.asarray(out.ids),
                                      np.asarray(base.ids), err_msg=msg)
        np.testing.assert_array_equal(np.asarray(out.dists),
                                      np.asarray(base.dists), err_msg=msg)
        for f in base.stats._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(out.stats, f)),
                np.asarray(getattr(base.stats, f)), err_msg=f"{msg} stats.{f}")


def test_load_never_rebuilds(index_path, monkeypatch):
    """load must not touch the graph builder or the PQ trainer."""
    from repro.core import engine as enginem

    def boom(*a, **k):
        raise AssertionError("load rebuilt index state")

    monkeypatch.setattr(enginem.graphm, "build_vamana", boom)
    monkeypatch.setattr(enginem.pqm, "train_pq", boom)
    eng = GateANNEngine.load(index_path)
    assert eng.codes.shape[0] == eng.vectors.shape[0]


def test_loaded_components_match(tiny_engine, mem_engine):
    np.testing.assert_array_equal(np.asarray(mem_engine.vectors),
                                  np.asarray(tiny_engine.vectors))
    np.testing.assert_array_equal(np.asarray(mem_engine.codes),
                                  np.asarray(tiny_engine.codes))
    np.testing.assert_array_equal(np.asarray(mem_engine.codec.books),
                                  np.asarray(tiny_engine.codec.books))
    np.testing.assert_array_equal(
        np.asarray(mem_engine.neighbor_store.neighbors),
        np.asarray(tiny_engine.neighbor_store.neighbors))
    assert int(mem_engine.medoid) == int(tiny_engine.medoid)
    assert set(mem_engine.filters) == set(tiny_engine.filters)
    assert mem_engine.config == tiny_engine.config


def test_load_config_overrides(index_path):
    eng = GateANNEngine.load(index_path, r_max=4)
    assert eng.neighbor_store.r_max == 4
    eng2 = GateANNEngine.load(index_path, {"r_max": 6})
    assert eng2.neighbor_store.r_max == 6
    # misspelled overrides must raise, not silently no-op
    with pytest.raises(ValueError, match="cache_budget"):
        GateANNEngine.load(index_path, cache_budget=1 << 20)


def test_save_over_live_disk_engine(index_path, tmp_path, tiny_corpus):
    """Re-saving onto the file backing a live disk engine must not corrupt
    the mapping mid-search (write-then-rename keeps the old inode)."""
    _, _, queries = tiny_corpus
    path = str(tmp_path / "live.gann")
    shutil.copyfile(index_path, path)
    disk = GateANNEngine.load(path, store_tier="disk")
    base = _search(disk, queries[:4], "gate")
    disk.save(path)  # overwrites the very file the memmap is backed by
    out = _search(disk, queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))
    # and a fresh load of the re-saved file agrees too
    out2 = _search(GateANNEngine.load(path, store_tier="disk"), queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out2.ids), np.asarray(base.ids))


# -- measured I/O -----------------------------------------------------------

def test_disk_pages_reconcile_and_gate_lt_post(disk_engine, tiny_corpus):
    """Measured sector reads == modeled n_ios; tunneling saves real pages."""
    _, _, queries = tiny_corpus
    store = disk_engine.record_store
    assert isinstance(store, DiskRecordStore)
    pages = {}
    for mode in ("gate", "post"):
        before = store.pages_read
        out = _search(disk_engine, queries, mode)
        ids = np.asarray(out.ids)  # materialize => all callbacks ran
        assert ids.shape[0] == queries.shape[0]
        measured = store.pages_read - before
        modeled = int(np.sum(np.asarray(out.stats.n_ios))) * store.pages_per_record
        assert measured == modeled, mode
        pages[mode] = measured
    assert pages["gate"] < pages["post"]
    assert store.bytes_read == store.pages_read * PAGE_BYTES
    assert store.records_read * store.pages_per_record == store.pages_read


def test_cache_tier_composes_on_disk(disk_engine, tiny_corpus):
    """A cache in front of the disk tier: identical ids, I/O conservation,
    and the file only ever sees the misses (measured)."""
    _, _, queries = tiny_corpus
    store = disk_engine.record_store
    base = _search(disk_engine, queries, "gate")
    base_ids = np.asarray(base.ids)
    base_ios = np.asarray(base.stats.n_ios)
    cached = disk_engine.with_cache(64 * RECORD)
    before = store.pages_read
    out = _search(cached, queries, "gate")
    ids = np.asarray(out.ids)
    measured = store.pages_read - before
    np.testing.assert_array_equal(ids, base_ids)
    ios = np.asarray(out.stats.n_ios)
    hits = np.asarray(out.stats.n_cache_hits)
    np.testing.assert_array_equal(ios + hits, base_ios)
    assert int(hits.sum()) > 0
    assert measured == int(ios.sum()) * store.pages_per_record


def test_adaptive_cache_composes_on_disk(disk_engine, tiny_corpus):
    _, _, queries = tiny_corpus
    base = _search(disk_engine, queries, "gate")
    eng = disk_engine.with_cache(64 * RECORD, policy="adaptive", refresh_every=1)
    for _ in range(2):
        out = _search(eng, queries, "gate")
        np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))
        np.testing.assert_array_equal(
            np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
            np.asarray(base.stats.n_ios))


def test_memory_report_disk_lines(disk_engine, index_path):
    rep = disk_engine.memory_report()
    assert rep["record_tier"] == "disk"
    assert rep["disk_path"] == index_path
    assert rep["disk_index_bytes"] == os.path.getsize(index_path)
    assert rep["record_tier_bytes"] == rep["n"] * rep["disk_sector_bytes"]
    assert rep["disk_pages_read"] >= 0
    assert rep["disk_bytes_read"] == rep["disk_pages_read"] * PAGE_BYTES


# -- sharded record segments ------------------------------------------------

@pytest.fixture(scope="module")
def sharded_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("sharded") / "tiny_sharded.gann")
    tiny_engine.save(path, shards=3)
    return path


def test_sharded_save_layout(sharded_path, tiny_engine):
    h = read_header(sharded_path)
    n = int(tiny_engine.vectors.shape[0])
    assert h.shards is not None and h.n_shards == 3
    assert h.shards["rows_per_shard"] == -(-n // 3)
    assert "records" not in h.sections  # records live in the segments
    covered = 0
    for i, seg in enumerate(h.shards["segments"]):
        assert os.path.exists(h.segment_path(i))
        assert seg["row_start"] == covered
        covered += seg["n_rows"]
    assert covered == n
    assert f"3 shards" in h.describe()
    # the monolithic accessor must fail loudly, not serve garbage
    with pytest.raises(IndexFormatError, match="sharded"):
        read_index(sharded_path).records()


@pytest.mark.parametrize("tier", ["memory", "disk"])
def test_sharded_roundtrip_bit_identical(sharded_path, tiny_engine,
                                         tiny_corpus, tier):
    _, _, queries = tiny_corpus
    eng = GateANNEngine.load(
        sharded_path, **({"store_tier": "disk"} if tier == "disk" else {})
    )
    for mode in ("gate", "post"):
        base = _search(tiny_engine, queries, mode)
        out = _search(eng, queries, mode)
        np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids),
                                      err_msg=f"{tier} {mode}")
        np.testing.assert_array_equal(np.asarray(out.dists),
                                      np.asarray(base.dists))


def test_sharded_disk_counters(sharded_path, tiny_corpus):
    """Coalescing works per segment: unique <= requested still holds and
    preadv spends at most one vectored call per touched segment per round."""
    _, _, queries = tiny_corpus
    eng = GateANNEngine.load(sharded_path, store_tier="disk")
    store = eng.record_store
    assert store.n_shards == 3
    out = _search(eng, queries, "gate")
    np.asarray(out.ids)  # materialize => all callbacks ran
    c = store.io_counters()
    assert c["records_read"] == int(np.sum(np.asarray(out.stats.n_ios)))
    assert 0 < c["unique_sectors_read"] <= c["records_read"]
    if store.io_mode == "preadv":
        assert c["read_rounds"] <= c["syscalls"] <= c["read_rounds"] * 3
    # footprint spans the main file plus every segment
    assert store.index_bytes() > os.path.getsize(sharded_path)


def test_shard_loader_parity(sharded_path, index_path, tiny_engine):
    """core.distributed_search loaders == ShardedRecordStore.shard_arrays
    over the live arrays — segment files feed the mesh byte-identically."""
    from repro.core.distributed_search import (
        load_shard_records,
        load_sharded_record_arrays,
    )
    from repro.store import ShardedRecordStore

    vecs = np.asarray(tiny_engine.vectors, np.float32)
    nbrs = np.asarray(tiny_engine.record_store.neighbors, np.int32)
    want_v, want_n, want_rows = ShardedRecordStore.shard_arrays(vecs, nbrs, 3)
    got_v, got_n, rows = load_sharded_record_arrays(sharded_path)
    assert rows == want_rows
    np.testing.assert_array_equal(got_v, want_v.astype(np.float32))
    np.testing.assert_array_equal(got_n, want_n.astype(np.int32))
    # one shard alone, off the sharded index and off the monolithic one
    for path, kw in ((sharded_path, {}), (index_path, {"n_shards": 3})):
        v1, n1, r1 = load_shard_records(path, 1, **kw)
        assert r1 == want_rows
        np.testing.assert_array_equal(v1, want_v[want_rows : 2 * want_rows])
        np.testing.assert_array_equal(n1, want_n[want_rows : 2 * want_rows])
    with pytest.raises(ValueError, match="out of range"):
        load_shard_records(sharded_path, 5)
    with pytest.raises(ValueError, match="n_shards"):
        load_shard_records(index_path, 0)


def test_sharded_segment_corruption_rejected(sharded_path, tmp_path):
    seg_names = [s["name"] for s in read_header(sharded_path).shards["segments"]]
    names = [os.path.basename(sharded_path)] + seg_names
    src_dir = os.path.dirname(sharded_path)

    def fresh(into):
        d = tmp_path / into
        d.mkdir()
        for nm in names:
            shutil.copyfile(os.path.join(src_dir, nm), str(d / nm))
        return str(d), str(d / names[0])

    # a missing segment file must fail the disk load loudly
    dd, p = fresh("missing")
    os.remove(os.path.join(dd, seg_names[1]))
    with pytest.raises(IndexFormatError, match="seg1"):
        GateANNEngine.load(p, store_tier="disk")
    # a truncated segment is caught before it serves short sectors
    dd, p = fresh("trunc")
    seg2 = os.path.join(dd, seg_names[2])
    os.truncate(seg2, os.path.getsize(seg2) // 2)
    with pytest.raises(IndexFormatError, match="truncated segment"):
        GateANNEngine.load(p, store_tier="disk")
    # a swapped/stale segment (header disagrees with the manifest slot)
    dd, p = fresh("swapped")
    shutil.copyfile(os.path.join(dd, seg_names[0]), os.path.join(dd, seg_names[1]))
    with pytest.raises(IndexFormatError, match="wrong/stale segment"):
        GateANNEngine.load(p, store_tier="disk")


def test_sharded_save_over_live_engine(sharded_path, tiny_corpus, tmp_path):
    """Re-saving a sharded index over itself must never touch the
    committed generation's segment files: the live engine keeps serving
    off its old inodes, a fresh load serves the new generation, and the
    superseded segments are swept after the commit."""
    _, _, queries = tiny_corpus
    d = tmp_path / "live_sharded"
    d.mkdir()
    names = [os.path.basename(sharded_path)] + [
        s["name"] for s in read_header(sharded_path).shards["segments"]
    ]
    for nm in names:
        shutil.copyfile(os.path.join(os.path.dirname(sharded_path), nm),
                        str(d / nm))
    path = str(d / names[0])
    live = GateANNEngine.load(path, store_tier="disk")
    base = _search(live, queries[:4], "gate")
    old_segs = set(names[1:])
    live.save(path, shards=2)  # different shard count, same index path
    # the live engine's generation was never overwritten
    out = _search(live, queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))
    # a fresh load serves the new 2-shard generation, bit-identically
    fresh = GateANNEngine.load(path, store_tier="disk")
    assert fresh.record_store.n_shards == 2
    out2 = _search(fresh, queries[:4], "gate")
    np.testing.assert_array_equal(np.asarray(out2.ids), np.asarray(base.ids))
    # stale segments were swept once the new manifest committed
    new_segs = {s["name"] for s in read_header(path).shards["segments"]}
    on_disk = {f for f in os.listdir(str(d)) if ".seg" in f}
    assert on_disk == new_segs
    assert not (old_segs & on_disk)


def test_lazy_vectors_on_disk_load(disk_engine, mem_engine):
    """A disk-tier load must NOT materialize the corpus on device: the
    engine's vectors stay a lazy host view, cache wiring gathers only hot
    rows, and only the explicit debug path transfers."""
    import jax

    v = disk_engine.vectors
    assert isinstance(v, np.ndarray) and not isinstance(v, jax.Array)
    assert is_lazy_host(v)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(mem_engine.vectors))
    cached = disk_engine.with_cache(32 * RECORD)
    assert is_lazy_host(cached.vectors)  # still lazy behind the cache
    assert isinstance(cached.record_store.cache_vectors, jax.Array)
    assert int(cached.record_store.cache_vectors.shape[0]) <= 32
    adaptive = disk_engine.with_cache(32 * RECORD, policy="adaptive")
    assert is_lazy_host(adaptive.record_store.vectors)
    dv = disk_engine.record_store.device_vectors()
    assert isinstance(dv, jax.Array)
    np.testing.assert_array_equal(np.asarray(dv), np.asarray(v))


def test_lazy_vectors_on_sharded_disk_load(sharded_path, mem_engine):
    """The lazy-vectors guarantee must survive sharding: the multi-segment
    view stays host-side, row gathers touch only the asked rows, and the
    cache tier still ships only the hot set to device."""
    import jax

    eng = GateANNEngine.load(sharded_path, store_tier="disk")
    v = eng.vectors
    assert not isinstance(v, (jax.Array, np.memmap))
    assert is_lazy_host(v)
    ref = np.asarray(mem_engine.vectors)
    assert v.shape == ref.shape and len(v) == ref.shape[0]
    # row gathers cross segment boundaries correctly (rows_per_shard
    # boundaries for n=2000 over 3 shards fall at 667 and 1334)
    picks = np.asarray([0, 1, 666, 667, 1333, 1334, 1999, 5])
    np.testing.assert_array_equal(v[picks], ref[picks])
    np.testing.assert_array_equal(v[3], ref[3])
    np.testing.assert_array_equal(v[10:20], ref[10:20])
    np.testing.assert_array_equal(np.asarray(v), ref)
    cached = eng.with_cache(32 * RECORD)
    assert is_lazy_host(cached.vectors)
    assert isinstance(cached.record_store.cache_vectors, jax.Array)
    assert int(cached.record_store.cache_vectors.shape[0]) <= 32


# -- the format itself ------------------------------------------------------

def test_header_layout(index_path, tiny_engine):
    h = read_header(index_path)
    n, d = tiny_engine.vectors.shape
    assert h.version == FORMAT_VERSION
    assert (h.n, h.dim) == (n, d)
    assert h.medoid == int(tiny_engine.medoid)
    assert h.sector_bytes == record_sector_bytes(h.dim, h.degree)
    assert h.config["r_max"] == tiny_engine.config.r_max
    for name, s in h.sections.items():
        assert s["offset"] % PAGE_BYTES == 0, name
        assert s["offset"] + s["nbytes"] <= h.file_bytes, name
    for expect in ("records", "neighbors", "pq_books", "pq_codes",
                   "filter_label", "filter_range"):
        assert expect in h.sections
    assert "tiny.gann" in h.describe()


def test_record_sectors_page_aligned(tiny_engine):
    vecs = np.asarray(tiny_engine.vectors[:5])
    nbrs = np.asarray(tiny_engine.record_store.neighbors[:5])
    rec = pack_records(vecs, nbrs)
    assert rec.dtype.itemsize % PAGE_BYTES == 0
    np.testing.assert_array_equal(rec["vec"], vecs.astype("<f4"))
    np.testing.assert_array_equal(rec["nbrs"], nbrs.astype("<i4"))
    np.testing.assert_array_equal(rec["deg"], (nbrs >= 0).sum(1))


def test_disk_fetch_matches_memory(disk_engine, tiny_engine):
    """The host callback returns the same bytes as the in-memory store."""
    import jax.numpy as jnp

    ids = jnp.asarray([[0, 1, 7, -1, 1999]], jnp.int32)
    vecs_d, nbrs_d = disk_engine.record_store.fetch_fn()(ids)
    vecs_m, nbrs_m = tiny_engine.record_store.fetch_fn()(ids)
    np.testing.assert_array_equal(np.asarray(vecs_d), np.asarray(vecs_m))
    np.testing.assert_array_equal(np.asarray(nbrs_d), np.asarray(nbrs_m))


def test_bad_magic_rejected(index_path, tmp_path):
    bad = str(tmp_path / "bad_magic.gann")
    shutil.copyfile(index_path, bad)
    with open(bad, "r+b") as f:
        f.write(b"NOPE")
    with pytest.raises(IndexFormatError, match="magic"):
        read_header(bad)
    with pytest.raises(IndexFormatError):
        GateANNEngine.load(bad)


def test_v1_file_still_reads(index_path, tmp_path, tiny_corpus, tiny_engine):
    """Back-compat: a v1 file (monolithic records, no shard manifest) must
    load and search bit-identically under the v2 reader.  An unsharded v2
    layout is byte-compatible with v1, so pinning the version field back
    to 1 reconstructs a genuine v1 file."""
    _, _, queries = tiny_corpus
    v1 = str(tmp_path / "v1.gann")
    shutil.copyfile(index_path, v1)
    with open(v1, "r+b") as f:
        f.seek(4)
        f.write(np.uint32(1).tobytes())
    h = read_header(v1)
    assert h.version == 1 and h.shards is None
    base = _search(tiny_engine, queries, "gate")
    for kw in ({}, {"store_tier": "disk"}):
        out = _search(GateANNEngine.load(v1, **kw), queries, "gate")
        np.testing.assert_array_equal(np.asarray(out.ids), np.asarray(base.ids))


def test_newer_version_rejected(index_path, tmp_path):
    bad = str(tmp_path / "vnext.gann")
    shutil.copyfile(index_path, bad)
    with open(bad, "r+b") as f:
        f.seek(4)
        f.write(np.uint32(FORMAT_VERSION + 1).tobytes())
    with pytest.raises(IndexFormatError, match="version"):
        GateANNEngine.load(bad)


def test_truncated_file_rejected(index_path, tmp_path):
    bad = str(tmp_path / "trunc.gann")
    shutil.copyfile(index_path, bad)
    h = read_header(index_path)
    os.truncate(bad, h.file_bytes // 2)
    with pytest.raises(IndexFormatError, match="truncat"):
        read_header(bad)
    with pytest.raises(IndexFormatError):
        GateANNEngine.load(bad, store_tier="disk")


def _write_raw_header(path, meta, pad_bytes=0):
    """A syntactically valid header with arbitrary (possibly bogus) meta."""
    import json

    from repro.store.format import HEADER_PAGES, _PRELUDE, FORMAT_MAGIC

    blob = json.dumps(meta).encode()
    prelude = np.zeros((), dtype=_PRELUDE)
    prelude["magic"] = FORMAT_MAGIC
    prelude["version"] = FORMAT_VERSION
    prelude["json_len"] = len(blob)
    with open(path, "wb") as f:
        f.write(prelude.tobytes())
        f.write(blob)
        f.write(b"\0" * (HEADER_PAGES * PAGE_BYTES - _PRELUDE.itemsize - len(blob)))
        f.write(b"\0" * pad_bytes)


@pytest.mark.parametrize("meta", [
    {},  # everything missing
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"records": {"offset": 16384}}},  # section missing nbytes
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 0, "medoid": 0,
     "sections": {}},  # zero sector size (would div-by-zero downstream)
    {"n": 4, "dim": -1, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},  # nonsensical geometry
    {"n": "lots", "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},  # ill-typed field
    {"n": 100000, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"records": {"offset": 16384, "nbytes": 4096,
                              "dtype": "record", "shape": [1]}}},
    # ^ lying records shape: nbytes fits the file but not n x sector
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 16384, "nbytes": 99,
                               "dtype": "<i4", "shape": [4, 8]}}},
    # ^ dtype x shape inconsistent with nbytes (would mmap wrong bytes)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"neighbors": {"offset": 16384, "nbytes": -5000,
                                "dtype": "<i4", "shape": [4, 2]}}},
    # ^ negative section size
    {"n": 4, "dim": 2000, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {}},
    # ^ sector_bytes inconsistent with dim/degree (record dtype would
    #   read past the section at the wrong pages_per_record)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 10 ** 9,
     "sections": {}},  # medoid out of [0, n)
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 0, "nbytes": 0,
                               "dtype": "<i4", "shape": [0, 0]}}},
    # ^ section claiming the header pages as data
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"pq_codes": {"offset": 16384, "nbytes": 4096,
                               "dtype": "<u1", "shape": [4096]},
                  "neighbors": {"offset": 16384, "nbytes": 4096,
                                "dtype": "<u1", "shape": [4096]}}},
    # ^ overlapping sections
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {},
     "shards": {"n_shards": 2, "rows_per_shard": 2, "segments": [
         {"name": "../evil.seg0", "row_start": 0, "n_rows": 2, "nbytes": 8192},
         {"name": "x.seg1", "row_start": 2, "n_rows": 2, "nbytes": 8192}]}},
    # ^ segment name escaping the index directory
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {},
     "shards": {"n_shards": 2, "rows_per_shard": 2, "segments": [
         {"name": "x.seg0", "row_start": 0, "n_rows": 3, "nbytes": 12288},
         {"name": "x.seg1", "row_start": 3, "n_rows": 1, "nbytes": 4096}]}},
    # ^ segment rows disagree with rows_per_shard
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {},
     "shards": {"n_shards": 2, "rows_per_shard": 2, "segments": [
         {"name": "x.seg0", "row_start": 0, "n_rows": 2, "nbytes": 999},
         {"name": "x.seg1", "row_start": 2, "n_rows": 2, "nbytes": 8192}]}},
    # ^ segment nbytes inconsistent with rows x sector
    {"n": 4, "dim": 2, "degree": 2, "sector_bytes": 4096, "medoid": 0,
     "sections": {"records": {"offset": 16384, "nbytes": 16384,
                              "dtype": "record", "shape": [4]}},
     "shards": {"n_shards": 2, "rows_per_shard": 2, "segments": [
         {"name": "x.seg0", "row_start": 0, "n_rows": 2, "nbytes": 8192},
         {"name": "x.seg1", "row_start": 2, "n_rows": 2, "nbytes": 8192}]}},
    # ^ both a monolithic records section AND a shard manifest
])
def test_corrupt_parseable_header_rejected(tmp_path, meta):
    """JSON that parses but lies must still come out as IndexFormatError."""
    p = str(tmp_path / "corrupt.gann")
    _write_raw_header(p, meta, pad_bytes=8192)
    with pytest.raises(IndexFormatError):
        read_header(p)


def test_not_an_index_rejected(tmp_path):
    p = str(tmp_path / "tiny.gann")
    with open(p, "wb") as f:
        f.write(b"hello world")
    with pytest.raises(IndexFormatError):
        read_header(p)
    with pytest.raises(IndexFormatError):
        read_index(os.path.join(str(tmp_path), "missing.gann"))
