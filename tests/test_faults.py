"""Fault injection + the resilient I/O path: retries, deadlines, degrade.

Contract under test (store/faults.py + the resilience layer threaded
through store/disk.py, core/search.py, serve/server.py):

  * The fault wrapper is *transparent* when inactive: a store opened
    with an all-zeros ``FaultPlan`` is bit-identical to an unwrapped
    store at every io_mode and pipeline depth — the injector routes
    every call but alters none.
  * Injected short reads are REAL truncated syscalls, so
    ``_preadv_full``/``_pread_full`` resume against genuine partial
    data: reassembly stays byte-exact and ``syscalls`` counts every
    completed call, including resumes and ``_IOV_MAX`` splits.
  * Transient errors (EIO/EAGAIN) retry under ``RetryPolicy`` with
    counted reattempts; exhausted retries either raise (``on_error=
    "fail"``) or degrade the failed read group to *tunneled* records —
    +inf vector sentinel, neighbors served from the adjacency sidecar —
    so traversal continues and only exact reranking skips the slot.
  * Degradation is fully accounted: ``degraded_records`` at the store,
    ``n_degraded`` per query in SearchStats, no token leaks
    (``abandoned_tokens == 0``) at any pipeline depth, and the logical
    counters keep counting *requested* records so the
    records_read == sum(n_ios) reconciliation survives faults.
  * The serve layer sheds expired requests (EDF order, counted
    ``deadline_shed``) and under ``fault_policy="retry_then_degrade"``
    no request fails while faults are injected.

Everything here runs scripted schedules (exact call indices), never
probabilities — tier-1 stays deterministic; probabilistic sweeps live
in benchmarks/chaos_matrix.py.
"""
import os

import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.store import DiskRecordStore, FaultPlan, RetryPolicy, is_transient
from repro.store import disk as diskm
from repro.store.disk import ReadDeadlineError


@pytest.fixture(scope="module")
def index_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("faults") / "tiny.gann")
    tiny_engine.save(path)
    return path


@pytest.fixture(scope="module")
def clean_store(index_path):
    return DiskRecordStore.open(index_path, io_mode="preadv")


def _cfg(depth=1, mode="gate"):
    return SearchConfig(mode=mode, search_l=32, beam_width=4,
                        pipeline_depth=depth)


def _label_params(nq, label=0):
    return np.full(nq, label, np.int32)


@pytest.fixture(scope="module")
def clean_search(index_path, tiny_corpus):
    """(ids, dists) of an unwrapped clean disk engine per pipeline depth —
    the bit-identity / overlap baseline, computed once for the module."""
    _, _, queries = tiny_corpus
    eng = GateANNEngine.load(index_path, store_tier="disk")
    fp = _label_params(len(queries))
    out = {}
    for depth in (1, 2):
        o = eng.search(queries, filter_kind="label", filter_params=fp,
                       search_config=_cfg(depth))
        out[depth] = (np.asarray(o.ids), np.asarray(o.dists))
    return out


# ------------------------------------------------------------- the plan --
def test_plan_validation():
    with pytest.raises(ValueError, match="probabilities"):
        FaultPlan(p_eio=0.8, p_short=0.5)
    with pytest.raises(ValueError, match="short_frac"):
        FaultPlan(short_frac=1.5)
    with pytest.raises(ValueError, match="schedule"):
        FaultPlan(schedule=((0, "nope"),))
    with pytest.raises(ValueError, match="schedule"):
        FaultPlan(schedule=((-1, "eio"),))
    assert not FaultPlan().active
    assert FaultPlan(p_eio=0.01).active
    assert FaultPlan(schedule=((3, "eio"),)).active


def test_plan_decisions_deterministic():
    """The injection decision is a pure function of (seed, call index):
    two injectors from the same plan agree call-for-call, a different
    seed diverges, and max_faults caps the total."""
    plan = FaultPlan(seed=42, p_eio=0.2, p_short=0.2)
    inj_a, inj_b = plan.injector(), plan.injector()
    a = [inj_a._decide() for _ in range(200)]
    b = [inj_b._decide() for _ in range(200)]
    assert a == b
    assert any(k is not None for k in a)  # 40% over 200 calls must fire
    inj_c = FaultPlan(seed=43, p_eio=0.2, p_short=0.2).injector()
    assert [inj_c._decide() for _ in range(200)] != a
    capped = FaultPlan(seed=42, p_eio=0.5, max_faults=3).injector()
    got = [capped._decide() for _ in range(200)]
    assert sum(k is not None for k in got) == 3


def test_schedule_fires_at_exact_indices():
    inj = FaultPlan(schedule=((2, "eio"), (5, "short"))).injector()
    got = [inj._decide() for _ in range(7)]
    assert got == [None, None, "eio", None, None, "short", None]
    c = inj.counters()
    assert c["read_calls"] == 7 and c["faults_injected"] == 2
    assert c["injected_eio"] == 1 and c["injected_short"] == 1


# --------------------------------------------- transparency (zero fault) --
@pytest.mark.parametrize("io_mode", ("preadv", "pread", "gather"))
def test_inactive_wrapper_is_bit_identical(index_path, clean_store, io_mode):
    """Wrapping the read path with an idle injector must change nothing:
    same bytes, same physical counters, calls routed and counted."""
    store = DiskRecordStore.open(index_path, io_mode=io_mode,
                                 faults=FaultPlan(seed=5))
    try:
        rng = np.random.default_rng(3)
        ids = rng.integers(-1, store.n, size=(6, 9)).astype(np.int32)
        vecs, nbrs = store._host_fetch(ids)
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(vecs, want_v)
        np.testing.assert_array_equal(nbrs, want_n)
        fc = store.fault_counters()
        assert fc["read_calls"] > 0 and fc["faults_injected"] == 0
        d = store.io_counters()
        assert d["degraded_records"] == 0 and d["retried_ios"] == 0
    finally:
        store.close()


# ------------------------------------------------------- short reads ------
@pytest.mark.parametrize("io_mode", ("preadv", "pread"))
def test_short_read_resume_is_byte_exact(index_path, clean_store, io_mode):
    """Scheduled short reads truncate the real syscall, so the resume
    loops re-issue for the remainder: bytes stay exact and ``syscalls``
    counts the extra completed calls."""
    plan = FaultPlan(seed=1, schedule=((0, "short"), (1, "short")),
                     short_frac=0.3)
    store = DiskRecordStore.open(index_path, io_mode=io_mode, faults=plan)
    try:
        ids = np.asarray([[2, 3, 4, 5, 6, 7, 8, 9]], np.int32)
        before = store.io_counters()
        vecs, nbrs = store._host_fetch(ids)
        d = {k: v - before[k] for k, v in store.io_counters().items()}
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(vecs, np.asarray(want_v))
        np.testing.assert_array_equal(nbrs, np.asarray(want_n))
        # one contiguous range = 1 clean call; two injected truncations
        # force at least two resume calls on top
        assert d["syscalls"] >= 3
        assert d["degraded_records"] == 0 and d["retried_ios"] == 0
        assert store.fault_counters()["injected_short"] == 2
    finally:
        store.close()


def test_short_reads_across_iov_max_boundary(index_path, clean_store,
                                             monkeypatch):
    """With _IOV_MAX forced tiny, a wide gappy beam splits into many
    vectored batches; shorts landing mid-batch must resume within the
    rest+pending recombination without corrupting any record."""
    monkeypatch.setattr(diskm, "_IOV_MAX", 3)
    plan = FaultPlan(seed=2, short_frac=0.5,
                     schedule=tuple((i, "short") for i in (0, 2, 5)))
    store = DiskRecordStore.open(index_path, io_mode="preadv", faults=plan)
    try:
        # every other sector: each record is its own range, so iovecs
        # (record + gap views) overflow the forced 3-entry batches
        ids = np.arange(0, 80, 2, dtype=np.int32)[None, :]
        before = store.io_counters()
        vecs, nbrs = store._host_fetch(ids)
        d = {k: v - before[k] for k, v in store.io_counters().items()}
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(vecs, np.asarray(want_v))
        np.testing.assert_array_equal(nbrs, np.asarray(want_n))
        assert store.fault_counters()["injected_short"] == 3
        # 40 wanted + 39 gap iovecs can't move in fewer than 27
        # 3-entry batches; the injected truncations add resume calls on
        # top (exact count depends on where the rest+pending recombine
        # lands relative to batch boundaries)
        assert d["syscalls"] >= 27
        assert d["degraded_records"] == 0
    finally:
        store.close()


# ------------------------------------------------- retries and degrade ---
def test_transient_taxonomy():
    assert is_transient(OSError(5, "eio"))  # EIO
    assert is_transient(OSError(11, "eagain"))
    assert is_transient(ReadDeadlineError("tripped"))
    assert not is_transient(OSError(2, "enoent"))
    assert not is_transient(IOError("unexpected EOF"))  # errno None: fatal


def test_eagain_absorbed_by_retry(index_path, clean_store):
    plan = FaultPlan(seed=1, schedule=((0, "eagain"),))
    store = DiskRecordStore.open(
        index_path, io_mode="preadv", faults=plan,
        retry=RetryPolicy(max_retries=2, backoff_s=1e-5),
    )
    try:
        ids = np.asarray([[10, 11, 12]], np.int32)
        vecs, nbrs = store._host_fetch(ids)
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(vecs, np.asarray(want_v))
        np.testing.assert_array_equal(nbrs, np.asarray(want_n))
        d = store.io_counters()
        assert d["retried_ios"] == 1 and d["retry_exhausted"] == 0
        assert d["degraded_records"] == 0
    finally:
        store.close()


def test_eio_degrades_group_to_tunneled_records(index_path, clean_store):
    """An exhausted EIO under on_error="degrade" fails the whole read
    group: vectors come back +inf (the tunnel sentinel — NaN would pass
    the INF comparison in results_insert), neighbors still come from the
    adjacency sidecar, and the logical counters keep counting what was
    REQUESTED so reconciliation survives."""
    plan = FaultPlan(seed=1, schedule=((0, "eio"),))
    store = DiskRecordStore.open(index_path, io_mode="preadv", faults=plan,
                                 on_error="degrade")
    try:
        ids = np.asarray([[20, 21, 22]], np.int32)
        before = store.io_counters()
        vecs, nbrs = store._host_fetch(ids)
        d = {k: v - before[k] for k, v in store.io_counters().items()}
        assert np.isinf(vecs).all()  # one group -> all three degraded
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(nbrs, np.asarray(want_n))  # sidecar
        assert d["records_read"] == 3  # logical counters: requested
        assert d["degraded_records"] == 3
        assert d["retry_exhausted"] == 1 and d["retried_ios"] == 0
        # the injector exhausted its schedule: the next fetch is clean
        vecs2, _ = store._host_fetch(ids)
        np.testing.assert_array_equal(vecs2, np.asarray(want_v))
    finally:
        store.close()


def test_fail_policy_raises_and_store_survives(index_path, clean_store):
    plan = FaultPlan(seed=1, schedule=((0, "eio"),))
    store = DiskRecordStore.open(index_path, io_mode="preadv", faults=plan)
    try:
        ids = np.asarray([[30, 31]], np.int32)
        with pytest.raises(OSError):
            store._host_fetch(ids)
        assert store.io_counters()["retry_exhausted"] == 1
        vecs, nbrs = store._host_fetch(ids)  # schedule spent: serves again
        want_v, want_n = clean_store._host_fetch(ids)
        np.testing.assert_array_equal(vecs, np.asarray(want_v))
        np.testing.assert_array_equal(nbrs, np.asarray(want_n))
    finally:
        store.close()


def test_round_deadline_degrades_remaining_groups(index_path):
    """A delay fault longer than the round deadline: the delayed group
    still lands, but the NEXT group's pre-issue deadline check trips and
    degrades it (counted once per round)."""
    plan = FaultPlan(seed=1, schedule=((0, "delay"),), delay_s=0.05)
    store = DiskRecordStore.open(
        index_path, io_mode="preadv", faults=plan, on_error="degrade",
        round_deadline_s=0.01, max_gap_sectors=2,
    )
    try:
        # sectors 0 and 1000: gap >> max_gap_sectors -> two preadv groups
        ids = np.asarray([[0, 1000]], np.int32)
        vecs, _ = store._host_fetch(ids)
        d = store.io_counters()
        assert d["deadline_trips"] == 1
        assert d["degraded_records"] == 1
        assert not np.isinf(vecs[0, 0]).any()  # first group landed
        assert np.isinf(vecs[0, 1]).all()  # second group degraded
    finally:
        store.close()


def test_configure_resilience_validation_and_effect(index_path):
    store = DiskRecordStore.open(index_path)
    try:
        with pytest.raises(ValueError, match="on_error"):
            store.configure_resilience(on_error="explode")
        store.configure_resilience(retry=RetryPolicy(max_retries=4),
                                   on_error="degrade", round_deadline_s=0.5)
        assert store.retry_policy.max_retries == 4
        assert store.on_error == "degrade"
        assert store.round_deadline_s == 0.5
    finally:
        store.close()


# ------------------------------------------------------ search-level -----
def test_zero_fault_search_bit_identical(index_path, tiny_corpus,
                                         clean_search):
    """FaultPlan(seed, all-zero probabilities) wrapped around the disk
    tier must leave search output bit-identical at every pipeline
    depth — the acceptance gate for wrapper transparency."""
    _, _, queries = tiny_corpus
    wrapped = GateANNEngine.load(index_path, store_tier="disk",
                                 faults=FaultPlan(seed=5))
    fp = _label_params(len(queries))
    for depth in (1, 2):
        out_w = wrapped.search(queries, filter_kind="label",
                               filter_params=fp, search_config=_cfg(depth))
        want_ids, want_dists = clean_search[depth]
        np.testing.assert_array_equal(want_ids, np.asarray(out_w.ids))
        np.testing.assert_array_equal(want_dists, np.asarray(out_w.dists))
        assert int(np.asarray(out_w.stats.n_degraded).sum()) == 0
    assert wrapped.record_store.fault_counters()["read_calls"] > 0


@pytest.mark.parametrize("depth", (1, 2))
def test_degraded_search_completes_and_accounts(index_path, tiny_corpus,
                                                clean_search, depth):
    """Scheduled EIOs under degrade: the search completes, degraded
    slots are counted per query, no pipelined token leaks, and the
    requested-records reconciliation holds."""
    _, _, queries = tiny_corpus
    plan = FaultPlan(seed=7, schedule=tuple((i, "eio") for i in (1, 3, 6)))
    eng = GateANNEngine.load(index_path, store_tier="disk",
                             io_on_error="degrade", faults=plan)
    store = eng.record_store
    fp = _label_params(len(queries))
    out = eng.search(queries, filter_kind="label", filter_params=fp,
                     search_config=_cfg(depth))
    stats = out.stats
    # materialize BEFORE reading counters: the ordered io_callbacks only
    # complete when the stats arrays do (same discipline as obs.stats)
    n_deg = int(np.asarray(stats.n_degraded).sum())
    d = store.io_counters()
    assert n_deg > 0
    assert d["degraded_records"] == n_deg
    assert d["abandoned_tokens"] == 0
    assert len(store._pending) == 0
    assert d["records_read"] == int(np.asarray(stats.n_ios).sum())
    # degraded slots were dropped from exact rerank, never served: every
    # returned id is a real record or the -1 pad
    ids = np.asarray(out.ids)
    assert ((ids >= -1) & (ids < store.n)).all()
    # graceful, not catastrophic: losing 3 of ~14 read rounds outright
    # (whole-round degradation is the conservative worst case — the
    # chaos benchmark sweeps the gentler probabilistic regimes) still
    # leaves substantial top-10 agreement with the clean run
    ref = clean_search[depth][0][:, :10]
    got = ids[:, :10]
    overlap = np.mean([
        len(set(got[i].tolist()) & set(ref[i].tolist())) / 10.0
        for i in range(len(ref))
    ])
    assert overlap >= 0.3


def test_degraded_search_records_obs_counters(index_path, tiny_corpus):
    from repro import obs

    _, _, queries = tiny_corpus
    plan = FaultPlan(seed=7, schedule=((2, "eio"),))
    reg = obs.MetricsRegistry(enabled=True)
    prev = obs.set_default_registry(reg)
    try:
        eng = GateANNEngine.load(index_path, store_tier="disk",
                                 io_on_error="degrade", faults=plan)
        eng.search(queries, filter_kind="label",
                   filter_params=_label_params(len(queries)),
                   search_config=_cfg(1))
    finally:
        obs.set_default_registry(prev)
    snap = reg.snapshot()

    def total(name):
        return snap.get(name, {}).get("total", 0)

    assert total("search.degraded") > 0
    assert total("search.degraded_queries") > 0
    assert total("disk.degraded_records") == total("search.degraded")
    assert total("disk.retry_exhausted") > 0


# ------------------------------------------------------------ serve ------
def _serve_setup(index_path, queries, plan=None, **fe_kwargs):
    from repro.serve import RAGServer, ServeFrontend, TenantSpec

    eng = GateANNEngine.load(index_path, store_tier="disk", faults=plan)
    rag = RAGServer(
        engine=eng, cfg=None, params=None, layout=None,
        passage_tokens=np.zeros((int(eng.vectors.shape[0]), 4), np.int32),
        search_config=_cfg(1), bucket_sizes=(4,),
    )
    tenants = [TenantSpec(f"t{i}", "label", np.int32(i), max_inflight=32)
               for i in range(2)]
    return eng, ServeFrontend(rag, tenants, max_batch=4,
                              batch_window_s=0.005, **fe_kwargs)


def test_serve_rejects_unknown_fault_policy(index_path, tiny_corpus):
    _, _, queries = tiny_corpus
    with pytest.raises(ValueError, match="fault_policy"):
        _serve_setup(index_path, queries, fault_policy="explode")


def test_serve_deadline_shed(index_path, tiny_corpus):
    """An already-expired deadline never reaches the engine: the batch
    former sheds it with DeadlineExceeded and counts the shed."""
    from repro.serve import DeadlineExceeded

    _, _, queries = tiny_corpus
    _, srv = _serve_setup(index_path, queries)
    with srv:
        h = srv.submit("t0", queries[0], deadline_s=0.0)
        with pytest.raises(DeadlineExceeded):
            h.result(timeout=30.0)
        ok = srv.submit("t0", queries[1])  # no deadline: still served
        assert ok.result(timeout=120.0) is not None
        rep = srv.io_report()
    assert rep["deadline_shed"] == 1
    assert rep["per_tenant"]["t0"]["deadline_shed"] == 1
    assert rep["completed"] == 1 and rep["failed"] == 1


def test_serve_retry_then_degrade_no_request_fails(index_path, tiny_corpus):
    """The headline chaos contract at tier-1 scale: scheduled EIO bursts
    under fault_policy="retry_then_degrade" — every request succeeds,
    degraded slots are attributed per tenant, nothing leaks."""
    _, _, queries = tiny_corpus
    plan = FaultPlan(seed=3, schedule=tuple((i, "eio") for i in (1, 2, 5)))
    eng, srv = _serve_setup(index_path, queries, plan=plan,
                            fault_policy="retry_then_degrade")
    with srv:
        handles = [srv.submit(f"t{i % 2}", queries[i]) for i in range(8)]
        results = [h.result(timeout=120.0) for h in handles]
        rep = srv.io_report()
    assert all(r is not None for r in results)
    assert rep["failed"] == 0 and rep["completed"] == 8
    assert rep["fault_policy"] == "retry_then_degrade"
    # retries absorbed back-to-back schedule entries (1,2): the retried
    # call at idx 2 hits the next scheduled fault, then succeeds at 3 —
    # whatever degraded got attributed, totals and traces agree
    assert rep["degraded"] == sum(
        t["degraded"] for t in rep["per_tenant"].values()
    )
    assert rep["degraded"] == sum(h.trace.n_degraded for h in handles)
    d = eng.measured_store().io_counters()
    assert d["abandoned_tokens"] == 0
    assert d["retried_ios"] > 0


# ----------------------------------------------------------- warm path ---
def test_warm_errors_counted_not_swallowed(index_path, tmp_path):
    """A vanished segment during warm is counted, not discarded — the
    silent `except OSError: pass` this PR removed."""
    import shutil

    src_dir = os.path.dirname(index_path)
    base = os.path.basename(index_path)
    dst = str(tmp_path / base)
    for name in os.listdir(src_dir):
        if name.startswith(base):
            shutil.copy(os.path.join(src_dir, name), str(tmp_path / name))
    store = DiskRecordStore.open(dst)
    try:
        assert store.io_counters()["warm_errors"] == 0
        # touch the read path first so segment fds/memmaps are open —
        # unlinked inodes then stay readable through them
        store._host_fetch(np.asarray([[0, 1]], np.int32))
        for seg in store._segments:
            os.unlink(seg.path)
        store.warm(background=False)
        assert store.io_counters()["warm_errors"] == len(store._segments)
        # reads still work through the pinned inodes
        vecs, _ = store._host_fetch(np.asarray([[0, 1]], np.int32))
        assert np.isfinite(vecs).all()
    finally:
        store.close()
