"""Pipelined disk search: parity oracle, overlap counters, completion queue.

Contract under test (core/search.py + store/disk.py):

  * ``SearchConfig.pipeline_depth > 1`` runs the two-stage software
    pipeline — stage A traverses off submit-time neighbor lists (the
    adjacency sidecar) while up to ``depth`` record reads stay in flight,
    stage B retires them FIFO into the exact-distance result heap.
    Output (ids, dists, stats) is **bit-identical** to the synchronous
    loop for every mode, io_mode, cache tier, and depth; ``depth=1`` IS
    the synchronous loop (no submission ever happens).
  * Logical counters keep reconciling exactly under overlap:
    ``pages_read == sum(n_ios) * pages_per_record`` at every depth, and
    ``unique_sectors_read <= records_read`` with reads in flight.
  * ``inflight_depth_max`` never exceeds the configured depth, and
    ``overlapped_rounds > 0`` whenever depth > 1 ran more than one round.
  * The completion queue (token -> Future) is lock-guarded: concurrent
    submit/drain through one shared store loses no updates, serves
    byte-identical records in any drain order, and a drain of an unknown
    token fails loudly.
"""
import threading

import numpy as np
import pytest

from repro.core import GateANNEngine, SearchConfig
from repro.store import DiskRecordStore

MODES = ("gate", "post", "early", "pre_naive", "unfiltered")
IO_MODES = ("preadv", "pread", "gather")


@pytest.fixture(scope="module")
def index_path(tiny_engine, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("pipeline") / "tiny.gann")
    tiny_engine.save(path)
    return path


@pytest.fixture(scope="module")
def disk_engine(index_path):
    return GateANNEngine.load(index_path, store_tier="disk")


@pytest.fixture(scope="module")
def sync_out(disk_engine, tiny_corpus):
    """Synchronous (depth-1) reference outputs, one per mode."""
    _, _, queries = tiny_corpus
    out = {}
    for mode in MODES:
        kind, params = _filter_for(mode, queries)
        out[mode] = disk_engine.search(
            queries, filter_kind=kind, filter_params=params,
            search_config=_cfg(mode, 1),
        )
        np.asarray(out[mode].ids)
    return out


def _cfg(mode, depth):
    return SearchConfig(mode=mode, search_l=32, beam_width=4,
                        pipeline_depth=depth)


def _filter_for(mode, queries):
    if mode == "unfiltered":
        return None, None
    return "label", np.zeros(queries.shape[0], np.int32)


def _assert_same(got, want, ctx):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids),
                                  err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists),
                                  err_msg=str(ctx))
    for f in want.stats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got.stats, f)), np.asarray(getattr(want.stats, f)),
            err_msg=f"{ctx}: stats.{f}",
        )


@pytest.mark.parametrize("mode", MODES)
def test_pipelined_parity_every_mode(disk_engine, tiny_corpus, sync_out, mode):
    """depth=2 is bit-identical to the synchronous loop in all five modes,
    and the logical counters keep reconciling exactly under overlap."""
    _, _, queries = tiny_corpus
    kind, params = _filter_for(mode, queries)
    store = disk_engine.record_store
    before = store.io_counters()
    out = disk_engine.search(queries, filter_kind=kind, filter_params=params,
                             search_config=_cfg(mode, 2))
    np.asarray(out.ids)  # materialize => all submitted reads retired
    after = store.io_counters()
    _assert_same(out, sync_out[mode], (mode, 2))
    d = {k: after[k] - before[k] for k in after}
    ppr = store.pages_per_record
    assert d["pages_read"] == int(np.sum(np.asarray(out.stats.n_ios))) * ppr
    assert d["unique_sectors_read"] <= d["records_read"]
    assert d["abandoned_tokens"] == 0  # happy path drains every round


def test_depth_sweep_and_degenerate_depth_one(disk_engine, tiny_corpus, sync_out):
    """Depths 2 and 4 match; depth 1 never even submits (it IS the
    synchronous loop, not a one-deep pipeline)."""
    _, _, queries = tiny_corpus
    kind, params = _filter_for("gate", queries)
    store = disk_engine.record_store
    for depth in (2, 4):
        out = disk_engine.search(queries, filter_kind=kind, filter_params=params,
                                 search_config=_cfg("gate", depth))
        _assert_same(out, sync_out["gate"], ("gate", depth))
    store.reset_io_counters()
    out = disk_engine.search(queries, filter_kind=kind, filter_params=params,
                             search_config=_cfg("gate", 1))
    np.asarray(out.ids)
    c = store.io_counters()
    assert c["inflight_depth_max"] == 0 and c["overlapped_rounds"] == 0
    _assert_same(out, sync_out["gate"], ("gate", 1))


def test_overlap_counters_bounded_by_depth(disk_engine, tiny_corpus):
    """inflight_depth_max <= depth (it's a high-water mark — reset first),
    and depth > 1 actually overlaps reads across rounds."""
    _, _, queries = tiny_corpus
    kind, params = _filter_for("gate", queries)
    store = disk_engine.record_store
    for depth in (2, 4):
        store.reset_io_counters()
        out = disk_engine.search(queries, filter_kind=kind, filter_params=params,
                                 search_config=_cfg("gate", depth))
        np.asarray(out.ids)
        c = store.io_counters()
        assert 2 <= c["inflight_depth_max"] <= depth, (depth, c)
        assert c["overlapped_rounds"] > 0, depth
        assert c["fetch_rounds"] == int(np.asarray(out.stats.n_hops)[0])


@pytest.mark.parametrize("io_mode", ("pread", "gather"))
def test_pipelined_parity_across_io_modes(index_path, tiny_corpus, sync_out,
                                          io_mode):
    """The async pair sits above the coalesced reader, so every io_mode
    pipelines bit-identically."""
    import dataclasses

    _, _, queries = tiny_corpus
    base = GateANNEngine.load(index_path, store_tier="disk")
    alt = dataclasses.replace(
        base, record_store=DiskRecordStore.open(index_path, io_mode=io_mode)
    )
    kind, params = _filter_for("gate", queries)
    out = alt.search(queries, filter_kind=kind, filter_params=params,
                     search_config=_cfg("gate", 4))
    _assert_same(out, sync_out["gate"], ("gate", io_mode, 4))
    alt.record_store.close()


@pytest.mark.parametrize("policy", ("visit_freq", "adaptive"))
def test_pipelined_parity_with_cache_tier(disk_engine, tiny_corpus, sync_out,
                                          policy):
    """The cached-mask split routes only the miss set through the async
    path: results match the synchronous cached engine bit-for-bit and I/O
    conservation holds (ios + hits == uncached ios)."""
    _, _, queries = tiny_corpus
    kind, params = _filter_for("gate", queries)
    # refresh_every=0: freeze the adaptive hot set so the sync reference
    # and the pipelined run see the same cache state (the control loop
    # itself is pinned in test_adaptive_cache)
    cached = disk_engine.with_cache(48 * 4096, policy=policy, refresh_every=0)
    ref = cached.search(queries, filter_kind=kind, filter_params=params,
                        search_config=_cfg("gate", 1))
    out = cached.search(queries, filter_kind=kind, filter_params=params,
                        search_config=_cfg("gate", 4))
    _assert_same(out, ref, ("gate", policy, 4))
    assert int(np.sum(np.asarray(out.stats.n_cache_hits))) > 0
    if policy == "adaptive":
        # the controller-level async passthroughs mirror fetch_fn /
        # cached_mask_fn (engine resolution goes through the per-bucket
        # store_for snapshot; these serve direct filtered_search callers)
        assert cached.record_store.submit_fn() is not None
        assert cached.record_store.drain_fn() is not None
    np.testing.assert_array_equal(
        np.asarray(out.stats.n_ios) + np.asarray(out.stats.n_cache_hits),
        np.asarray(sync_out["gate"].stats.n_ios),
    )


def test_memory_tier_falls_back_to_sync(tiny_engine, tiny_corpus, sync_out):
    """A store without the async pair ignores pipeline_depth (results are
    bit-identical anyway — the disk tier is pinned to in-memory already)."""
    _, _, queries = tiny_corpus
    kind, params = _filter_for("gate", queries)
    out = tiny_engine.search(queries, filter_kind=kind, filter_params=params,
                             search_config=_cfg("gate", 4))
    np.testing.assert_array_equal(np.asarray(out.ids),
                                  np.asarray(sync_out["gate"].ids))


def test_submit_neighbors_match_record_neighbors(index_path):
    """The adjacency sidecar rows submit() returns are byte-identical to
    the nbrs field of the record sectors — the property that makes the
    pipelined traversal bit-identical."""
    store = DiskRecordStore.open(index_path)
    rng = np.random.default_rng(3)
    ids = rng.integers(-1, store.n, size=(5, 7)).astype(np.int32)
    token, nbrs = store._host_submit(ids)
    vecs = store._host_drain(token, ids, True)
    want_v, want_n = store._host_fetch(ids)
    np.testing.assert_array_equal(nbrs, want_n)
    np.testing.assert_array_equal(vecs, want_v)
    store.close()


def test_drain_unknown_token_raises(index_path):
    store = DiskRecordStore.open(index_path)
    ids = np.zeros((1, 2), np.int32)
    with pytest.raises(KeyError, match="unknown token"):
        store._host_drain(np.int32(10**6), ids, True)
    # a flag=False drain is the warmup no-op: zeros, queue untouched
    z = store._host_drain(np.int32(10**6), ids, False)
    assert (z == 0).all()
    store.close()


def test_completion_queue_lock_hammer(index_path):
    """Concurrent submit/drain through one shared store: every token
    resolves to the right round's records regardless of drain order, no
    counter updates are lost, and nothing deadlocks."""
    store = DiskRecordStore.open(index_path)
    ref_mm = {}  # id -> expected record, filled from the gather oracle
    oracle = DiskRecordStore.open(index_path, io_mode="gather")
    rng = np.random.default_rng(23)
    n_threads, per_thread, pipe = 6, 5, 3
    beams = {
        t: [rng.integers(-1, store.n, size=(3, 4)).astype(np.int32)
            for _ in range(per_thread)]
        for t in range(n_threads)
    }
    errs = []

    def hammer(tid):
        try:
            rng_t = np.random.default_rng(tid)
            pending = []
            for beam in beams[tid]:
                token, nbrs = store._host_submit(beam)
                want_v, want_n = oracle._host_fetch(beam)
                np.testing.assert_array_equal(nbrs, want_n)
                pending.append((token, beam, want_v))
                if len(pending) >= pipe:  # drain a RANDOM in-flight round
                    k = int(rng_t.integers(0, len(pending)))
                    tok, ids, want = pending.pop(k)
                    got = store._host_drain(tok, ids, True)
                    np.testing.assert_array_equal(got, want)
            for tok, ids, want in pending:
                got = store._host_drain(tok, ids, True)
                np.testing.assert_array_equal(got, want)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    want_records = sum(int((b >= 0).sum())
                       for bs in beams.values() for b in bs)
    c = store.io_counters()
    assert c["records_read"] == want_records
    assert c["fetch_rounds"] == n_threads * per_thread
    assert c["inflight_depth_max"] >= pipe  # the pipes genuinely filled
    assert c["abandoned_tokens"] == 0  # every round was properly drained
    assert len(store._pending) == 0  # the completion queue drained dry
    store.close()
    oracle.close()


def test_abandon_pending_drains_orphaned_rounds(index_path):
    """The mid-search-failure path: submitted-but-undrained rounds must be
    drain-or-cancelled (no leaked executor slots), counted in
    ``abandoned_tokens``, and the store must stay fully usable after."""
    store = DiskRecordStore.open(index_path)
    rng = np.random.default_rng(7)
    beams = [rng.integers(-1, store.n, size=(2, 3)).astype(np.int32)
             for _ in range(3)]
    tokens = [store._host_submit(b)[0] for b in beams]  # never drained
    assert len(store._pending) == len(beams)
    n = store.abandon_pending()
    assert n == len(beams)
    assert store.io_counters()["abandoned_tokens"] == len(beams)
    assert len(store._pending) == 0 and store._inflight == 0
    # an abandoned token is gone — a late drain fails loudly, not silently
    with pytest.raises(KeyError, match="unknown token"):
        store._host_drain(tokens[0], beams[0], True)
    # the reader pool survived: a fresh submit/drain round works, and a
    # whole pipelined search still runs clean on this same store
    token, _ = store._host_submit(beams[0])
    got = store._host_drain(token, beams[0], True)
    want_v, _ = store._host_fetch(beams[0])
    np.testing.assert_array_equal(got, want_v)
    assert store.abandon_pending() == 0  # idempotent when nothing pending
    store.close()


def test_engine_abandons_on_midsearch_failure(index_path, tiny_corpus,
                                              monkeypatch):
    """A stage-A failure with a round in flight must not leak the token:
    engine.search's failure path abandons it (abandoned_tokens counts it)
    and the engine serves the next search normally."""
    from repro.core import search as searchm

    _, _, queries = tiny_corpus
    engine = GateANNEngine.load(index_path, store_tier="disk")
    store = engine.record_store
    kind, params = _filter_for("gate", queries)
    # leave a genuinely in-flight round, as a failing stage A would
    store._host_submit(np.zeros((1, 2), np.int32))

    def boom(*args, **kwargs):
        raise RuntimeError("stage A failed mid-search")

    monkeypatch.setattr(searchm, "filtered_search", boom)
    with pytest.raises(RuntimeError, match="stage A failed"):
        engine.search(queries, filter_kind=kind, filter_params=params,
                      search_config=_cfg("gate", 2))
    assert store.io_counters()["abandoned_tokens"] >= 1
    assert len(store._pending) == 0  # nothing left pinning reader slots
    monkeypatch.undo()
    out = engine.search(queries, filter_kind=kind, filter_params=params,
                        search_config=_cfg("gate", 2))
    assert np.asarray(out.ids).shape[0] == queries.shape[0]


@pytest.mark.slow
def test_full_parity_lattice(index_path, tiny_corpus):
    """Nightly: the complete mode x io_mode x cache tier x depth lattice,
    pipelined pinned to synchronous everywhere."""
    _, _, queries = tiny_corpus
    for io_mode in IO_MODES:
        import dataclasses

        base = GateANNEngine.load(index_path, store_tier="disk")
        eng = dataclasses.replace(
            base, record_store=DiskRecordStore.open(index_path, io_mode=io_mode)
        )
        for cache in (None, "visit_freq", "adaptive"):
            # refresh_every=0 freezes the adaptive hot set: the cache is a
            # control loop, so without it the ref and pipelined runs would
            # (legitimately) see different hot sets and different n_ios
            e = eng if cache is None else eng.with_cache(
                48 * 4096, policy=cache, refresh_every=0)
            for mode in MODES:
                kind, params = _filter_for(mode, queries)
                ref = e.search(queries, filter_kind=kind, filter_params=params,
                               search_config=_cfg(mode, 1))
                for depth in (2, 4):
                    out = e.search(
                        queries, filter_kind=kind, filter_params=params,
                        search_config=_cfg(mode, depth),
                    )
                    _assert_same(out, ref, (io_mode, cache, mode, depth))
        eng.record_store.close()
